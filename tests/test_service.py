"""The scheduler service: state machine, journal stores, queue manager,
daemon-vs-``schedule_arrivals`` identity, and crash recovery by replay."""
import dataclasses

import numpy as np
import pytest

from repro.core import (Cluster, Job, PlacementState, ScheduleRequest,
                        get_policy, philly_cluster, philly_workload,
                        simulate)
from repro.service import (Daemon, InvalidTransition, JobRecord, JobState,
                           MemoryStore, QueueManager, SchedulerService,
                           SqliteStore, SubmitRequest, TenantConfig)


def _jobs(n, seed=3):
    jobs = philly_workload(seed=seed)[:n]
    return [dataclasses.replace(j, jid=i) for i, j in enumerate(jobs)]


def _arrivals(n, hi=120, seed=0):
    rng = np.random.default_rng(seed)
    return np.sort(rng.integers(0, hi, size=n)).astype(np.int64)


def _submit_all(svc, jobs, arrivals, tenant="default"):
    for j, a in zip(jobs, arrivals):
        svc.submit(SubmitRequest(j, int(a), tenant))


def _same_schedule(a, b):
    return (np.array_equal(a.est_start, b.est_start)
            and np.array_equal(a.est_finish, b.est_finish)
            and len(a.assignment) == len(b.assignment)
            and all(ja == jb and np.array_equal(ga, gb)
                    for (ja, ga), (jb, gb) in zip(a.assignment,
                                                  b.assignment)))


class TestStateMachine:
    def test_normal_lifecycle(self):
        rec = JobRecord(jid=0, tenant="t", job=_jobs(1)[0], arrival=0)
        for state in (JobState.QUEUED, JobState.PLACING, JobState.RUNNING,
                      JobState.DONE):
            rec.advance(state)
        assert rec.state is JobState.DONE

    def test_illegal_transitions_raise(self):
        rec = JobRecord(jid=0, tenant="t", job=_jobs(1)[0], arrival=0)
        with pytest.raises(InvalidTransition):
            rec.advance(JobState.RUNNING)       # PENDING -> RUNNING
        rec.advance(JobState.QUEUED)
        with pytest.raises(InvalidTransition):
            rec.advance(JobState.DONE)          # QUEUED -> DONE
        rec.advance(JobState.CANCELLED)
        with pytest.raises(InvalidTransition):
            rec.advance(JobState.QUEUED)        # terminal

    def test_requeue_voids_placement(self):
        """PLACING -> QUEUED (the crash re-enqueue) clears any partially
        recorded placement so recovery re-derives it from scratch."""
        rec = JobRecord(jid=0, tenant="t", job=_jobs(1)[0], arrival=0)
        rec.advance(JobState.QUEUED)
        rec.advance(JobState.PLACING)
        rec.gpus, rec.rho, rec.start = np.arange(2), 3.0, 1.0
        rec.advance(JobState.QUEUED)
        assert rec.gpus is None and rec.rho is None and rec.start is None


class TestStores:
    def test_memory_append_and_prefix(self):
        store = MemoryStore()
        for i in range(5):
            e = store.append("transition", i, {"to": "QUEUED"}, ts=float(i))
            assert e.seq == i + 1
        assert len(store) == 5
        snap = store.prefix(3)
        assert [e.seq for e in snap.entries()] == [1, 2, 3]
        # snapshots are independent copies
        snap.append("advance", -1, {"t": 9})
        assert len(store) == 5

    def test_sqlite_roundtrip_exact_floats(self, tmp_path):
        path = str(tmp_path / "journal.db")
        store = SqliteStore(path)
        rho = 0.1 + 0.2                      # 0.30000000000000004
        store.append("transition", 7,
                     {"to": "RUNNING", "gpus": [3, 4], "rho": rho,
                      "start": 17.0}, ts=1.5)
        store.close()
        back = SqliteStore(path)
        (entry,) = back.entries()
        assert entry.jid == 7 and entry.ts == 1.5
        assert entry.payload["rho"] == rho          # bitwise round-trip
        assert entry.payload["gpus"] == [3, 4]
        back.close()


class TestQueueManager:
    def test_visit_order_matches_schedule_arrivals(self):
        """Batches pop in (arrival, G_j, jid) order -- the epoch loop's
        sort key -- whatever order jobs were pushed in."""
        jobs = _jobs(6)
        qm = QueueManager(round_slots=10**6)
        order = [(5, jobs[0]), (1, jobs[3]), (1, jobs[1]), (0, jobs[2]),
                 (1, jobs[5]), (0, jobs[4])]
        for arrival, job in order:
            rec = JobRecord(jid=job.jid, tenant="t", job=job,
                            arrival=arrival)
            rec.advance(JobState.QUEUED)
            qm.push(rec)
        batch = qm.next_batch()
        keys = [(r.arrival, r.job.num_gpus, r.jid) for r in batch]
        assert keys == sorted(keys)

    def test_round_slots_and_max_batch(self):
        jobs = _jobs(6)
        qm = QueueManager(round_slots=2, max_batch=2)
        for i, job in enumerate(jobs):
            rec = JobRecord(jid=job.jid, tenant="t", job=job, arrival=i)
            rec.advance(JobState.QUEUED)
            qm.push(rec)
        first = qm.next_batch()
        # arrivals 0..5, round covers [0, 2) but max_batch caps at 2
        assert [r.arrival for r in first] == [0, 1]
        assert len(qm) == 4

    def test_cancel_is_lazy_but_effective(self):
        jobs = _jobs(3)
        qm = QueueManager(round_slots=10)
        for i, job in enumerate(jobs):
            rec = JobRecord(jid=job.jid, tenant="t", job=job, arrival=i)
            rec.advance(JobState.QUEUED)
            qm.push(rec)
        assert qm.cancel(1)
        assert not qm.cancel(1)              # already gone
        assert len(qm) == 2
        assert [r.jid for r in qm.next_batch()] == [0, 2]


class TestDaemonIdentity:
    """The tentpole property: the daemon path reproduces the one-shot
    online epoch loop decision-for-decision."""

    @pytest.mark.parametrize("policy,params", [
        ("sjf-bco", {}),
        ("ff", {}),
        ("ls", {}),
        ("rand", {"seed": 7}),
        ("reserved", {}),
    ])
    def test_drain_equals_schedule_arrivals(self, policy, params):
        cluster = philly_cluster(8, seed=1)
        jobs = _jobs(24)
        arrivals = _arrivals(len(jobs))
        ref = get_policy(policy)(ScheduleRequest(
            cluster, jobs, arrivals=arrivals, params=dict(params)))
        svc = SchedulerService(cluster, policy=policy, params=params)
        _submit_all(svc, jobs, arrivals)
        sched, sim = svc.drain()
        assert _same_schedule(ref, sched)
        ref_sim = simulate(cluster, jobs, ref.assignment, arrivals=arrivals)
        assert sim.completed == len(jobs)
        assert np.array_equal(sim.finish, ref_sim.finish)

    def test_batching_knobs_do_not_change_decisions(self):
        """Wider rounds / capped batches slice the stream differently but
        never reorder it, so the schedule is invariant."""
        cluster = philly_cluster(6, seed=2)
        jobs = _jobs(20)
        arrivals = _arrivals(len(jobs), hi=60)
        ref = get_policy("sjf-bco")(ScheduleRequest(cluster, jobs,
                                                    arrivals=arrivals))
        for kw in ({"round_slots": 5}, {"round_slots": 10**6},
                   {"max_batch": 1}, {"round_slots": 7, "max_batch": 3}):
            svc = SchedulerService(cluster, policy="sjf-bco", **kw)
            _submit_all(svc, jobs, arrivals)
            sched, _ = svc.drain()
            assert _same_schedule(ref, sched), kw

    def test_multi_tenant_choosers(self):
        """Tenants resolve their own policy through the core chooser
        registry while sharing one placement state."""
        cluster = philly_cluster(6, seed=2)
        jobs = _jobs(12)
        arrivals = _arrivals(len(jobs), hi=40)
        svc = SchedulerService(
            cluster, policy="sjf-bco",
            tenants={"best-effort": TenantConfig(policy="ff")})
        for i, (j, a) in enumerate(zip(jobs, arrivals)):
            svc.submit(SubmitRequest(
                j, int(a), "best-effort" if i % 3 == 0 else "default"))
        sched, sim = svc.drain()
        assert sim.completed == len(jobs)
        assert len(sched.assignment) == len(jobs)
        assert len(svc.daemon._choosers) == 2

    def test_cancel_mid_queue(self):
        cluster = philly_cluster(6, seed=2)
        jobs = _jobs(8)
        svc = SchedulerService(cluster, policy="sjf-bco")
        handles = [svc.submit(SubmitRequest(j, 10 + i))
                   for i, j in enumerate(jobs)]
        assert svc.cancel(handles[3])
        sched, sim = svc.drain()
        st = svc.status(handles[3], refresh=False)
        assert st.state is JobState.CANCELLED
        assert sched.est_start[3] == -1.0           # never placed
        placed = {j for j, _ in sched.assignment}
        assert placed == set(range(len(jobs))) - {3}
        # RUNNING/DONE jobs cannot be cancelled (non-preemptive gangs)
        assert not svc.cancel(handles[0])

    def test_status_and_monitor(self):
        cluster = philly_cluster(6, seed=2)
        jobs = _jobs(6)
        svc = SchedulerService(cluster, policy="sjf-bco")
        handles = [svc.submit(SubmitRequest(j, i)) for i, j in
                   enumerate(jobs)]
        while svc.step():
            pass
        st = svc.status(handles[0])         # refresh runs the monitor
        assert st.state in (JobState.RUNNING, JobState.DONE)
        assert st.gpus is not None and st.start is not None
        _, sim = svc.drain()
        for h in handles:
            done = svc.status(h, refresh=False)
            assert done.state is JobState.DONE
            assert done.finish == float(sim.finish[h.jid])
        assert "DONE" in svc.table()

    def test_decision_latencies_recorded(self):
        cluster = philly_cluster(4, seed=1)
        jobs = _jobs(5)
        svc = SchedulerService(cluster, policy="sjf-bco")
        _submit_all(svc, jobs, np.zeros(len(jobs), dtype=np.int64))
        svc.drain()
        lats = svc.daemon.decision_latencies
        assert len(lats) == len(jobs) and all(t > 0 for t in lats)

    def test_feedback_actual_runs_and_observes(self):
        """The opt-in completion-feedback mode executes end to end; it
        deliberately reprices later placements, so no identity claim --
        but every job still completes after its arrival."""
        cluster = philly_cluster(6, seed=2)
        jobs = _jobs(16)
        arrivals = _arrivals(len(jobs), hi=200)
        svc = SchedulerService(cluster, policy="sjf-bco",
                               feedback="actual")
        _submit_all(svc, jobs, arrivals)
        sched, sim = svc.drain()
        assert sim.completed == len(jobs)
        assert np.all(sim.start >= arrivals)

    def test_unknown_feedback_mode_rejected(self):
        with pytest.raises(ValueError, match="feedback"):
            SchedulerService(philly_cluster(4, seed=1), feedback="oracle")


class TestObserveFinish:
    """``feedback="actual"`` repricing: after ``observe_finish`` the
    rho-hat snapshot and real-time clocks match a hand-computed
    pull-back."""

    @staticmethod
    def _job(jid, gpus):
        return Job(jid=jid, num_gpus=gpus, iters=1000, grad_size=1e-3,
                   batch=32, dt_fwd=1e-4, dt_bwd=1e-3)

    def test_hand_computed_pullback(self):
        cluster = Cluster(capacities=(4, 4))
        state = PlacementState(cluster)
        # Job 0 straddles both servers: y = [4, 2], 0 < y_s < G on both.
        a = self._job(0, 6)
        gpus_a = np.arange(6)
        state.commit(a, gpus_a, rho=12.0, start=0.0, u=1.5)
        # Job 1 then reuses GPUs 3,4 (one per server, itself a straddler)
        # -- their real-time clocks now belong to job 1, so job 0's
        # pull-back must NOT touch them.
        b = self._job(1, 2)
        state.commit(b, np.array([3, 4]), rho=3.0, start=12.0, u=1.5)
        assert state._straddle_fin == [[12.0, 15.0], [12.0, 15.0]]

        state.observe_finish(a, gpus_a, 9.5)

        assert state.est_finish[0] == 9.5            # snapshot repriced
        # Straddler suffix lists: job 0's 12.0 replaced by 9.5 on both
        # servers; job 1's 15.0 entries untouched.
        assert state._straddle_fin == [[9.5, 15.0], [9.5, 15.0]]
        # GPUs whose R was last written by job 0 pull back to 9.5 ...
        assert np.array_equal(state.R[[0, 1, 2, 5]], np.full(4, 9.5))
        # ... but GPUs 3,4 keep job 1's later clock, and busy-time U is
        # never rewritten (Eq. 15 already charged rho/u at commit).
        assert np.array_equal(state.R[[3, 4]], np.full(2, 15.0))
        expect_u = np.zeros(8)
        expect_u[:6] += 12.0 / 1.5
        expect_u[3:5] += 3.0 / 1.5
        assert np.array_equal(state.U, expect_u)
        # A second observation of the SAME finish is a no-op.
        before = [list(f) for f in state._straddle_fin]
        state.observe_finish(a, gpus_a, 9.5)
        assert state._straddle_fin == before

    def test_idempotent_when_estimate_was_exact(self):
        cluster = Cluster(capacities=(4, 4))
        state = PlacementState(cluster)
        a = self._job(0, 6)
        gpus = np.arange(6)
        state.commit(a, gpus, rho=10.0, start=0.0, u=1.5)
        r_before = state.R.copy()
        state.observe_finish(a, gpus, 10.0)          # finish == estimate
        assert np.array_equal(state.R, r_before)
        assert state._straddle_fin == [[10.0], [10.0]]


class TestCrashRecovery:
    def test_fault_injection_every_journal_prefix(self):
        """Kill the daemon after EVERY journaled event; recovery plus the
        remaining submissions must reproduce the uninterrupted schedule
        exactly -- including crashes inside the PLACING window."""
        cluster = philly_cluster(8, seed=1)
        jobs = _jobs(16)
        arrivals = _arrivals(len(jobs))
        svc = SchedulerService(cluster, policy="sjf-bco")
        _submit_all(svc, jobs, arrivals)
        full, _ = svc.drain()
        store = svc.daemon.store
        placing_seen = 0
        for k in range(len(store) + 1):
            snap = store.prefix(k)
            if snap.entries() and snap.entries()[-1].kind == "transition" \
                    and snap.entries()[-1].payload["to"] == "PLACING":
                placing_seen += 1
            daemon = Daemon.recover(cluster, snap,
                                    QueueManager(TenantConfig("sjf-bco")))
            for j, a in list(zip(jobs, arrivals))[len(daemon.jobs):]:
                daemon.admit(j, int(a))
            sched, _ = daemon.drain()
            assert _same_schedule(full, sched), f"prefix {k}"
        assert placing_seen > 0     # the interesting crash window was hit

    def test_rand_recovery_replays_rng_decisions(self):
        """Stateful RAND: the rng snapshot journaled inside each outcome
        transition restores the generator, so killing the daemon after
        EVERY journal prefix still reproduces the stochastic schedule
        decision-for-decision -- the same guarantee the deterministic
        policies get."""
        cluster = philly_cluster(8, seed=1)
        jobs = _jobs(14)
        arrivals = _arrivals(len(jobs))
        svc = SchedulerService(cluster, policy="rand", params={"seed": 11})
        _submit_all(svc, jobs, arrivals)
        full, _ = svc.drain()
        store = svc.daemon.store
        rng_snapshots = sum(1 for e in store.entries()
                            if e.kind == "transition" and "rng" in e.payload)
        assert rng_snapshots == len(jobs)   # one per decision outcome
        cfg = TenantConfig("rand", params=(("seed", 11),))
        for k in range(len(store) + 1):
            daemon = Daemon.recover(cluster, store.prefix(k),
                                    QueueManager(cfg))
            for j, a in list(zip(jobs, arrivals))[len(daemon.jobs):]:
                daemon.admit(j, int(a))
            sched, _ = daemon.drain()
            assert _same_schedule(full, sched), f"prefix {k}"

    def test_sqlite_rng_state_roundtrip(self, tmp_path):
        """PCG64 state ints (128-bit) survive the sqlite JSON round-trip,
        so a reopened store recovers RAND exactly too."""
        cluster = philly_cluster(6, seed=2)
        jobs = _jobs(10)
        arrivals = _arrivals(len(jobs), hi=60)
        path = str(tmp_path / "rand.db")
        svc = SchedulerService(cluster, policy="rand", params={"seed": 5},
                               store_path=path)
        _submit_all(svc, jobs, arrivals)
        full, _ = svc.drain()
        svc.close()
        cfg = TenantConfig("rand", params=(("seed", 5),))
        back = SqliteStore(path)
        daemon = Daemon.recover(cluster, back, QueueManager(cfg))
        live = svc.daemon._choosers["default"].get_state()
        assert daemon._choosers["default"].get_state() == live
        back.close()

    def test_sqlite_crash_and_reopen(self, tmp_path):
        cluster = philly_cluster(8, seed=1)
        jobs = _jobs(16)
        arrivals = _arrivals(len(jobs))
        ref = get_policy("sjf-bco")(ScheduleRequest(cluster, jobs,
                                                    arrivals=arrivals))
        path = str(tmp_path / "svc.db")
        svc = SchedulerService(cluster, policy="sjf-bco", store_path=path)
        _submit_all(svc, jobs[:10], arrivals[:10])
        for _ in range(3):
            svc.step()
        svc.close()                          # process dies mid-stream
        rec = SchedulerService.recover(cluster, path, policy="sjf-bco")
        assert len(rec.daemon.jobs) == 10
        for j, a in list(zip(jobs, arrivals))[10:]:
            rec.submit(SubmitRequest(j, int(a)))
        sched, sim = rec.drain()
        rec.close()
        assert _same_schedule(ref, sched)
        assert sim.completed == len(jobs)

    def test_recovered_clocks_bit_identical(self):
        """Replay re-commits the exact journaled floats in order, so the
        recovered busy-time clocks equal the live daemon's bitwise."""
        cluster = philly_cluster(8, seed=1)
        jobs = _jobs(12)
        arrivals = _arrivals(len(jobs))
        svc = SchedulerService(cluster, policy="sjf-bco")
        _submit_all(svc, jobs, arrivals)
        while svc.step():
            pass
        live = svc.daemon
        recovered = Daemon.recover(cluster, live.store.prefix(
            len(live.store)), QueueManager(TenantConfig("sjf-bco")))
        assert np.array_equal(live.state.U, recovered.state.U)
        assert np.array_equal(live.state.R, recovered.state.R)
        assert live.state.est_finish == recovered.state.est_finish

    def test_recovery_preserves_cancellations_and_tenants(self):
        cluster = philly_cluster(6, seed=2)
        jobs = _jobs(8)
        svc = SchedulerService(
            cluster, tenants={"t2": TenantConfig(policy="ff")})
        handles = [svc.submit(SubmitRequest(j, 50 + i,
                                            "t2" if i % 2 else "default"))
                   for i, j in enumerate(jobs)]
        svc.cancel(handles[5])
        snap = svc.daemon.store.prefix(len(svc.daemon.store))
        rec = Daemon.recover(
            cluster, snap,
            QueueManager(TenantConfig("sjf-bco"),
                         {"t2": TenantConfig(policy="ff")}))
        assert rec.records[5].state is JobState.CANCELLED
        assert rec.records[1].tenant == "t2"
        full, _ = svc.drain()
        again, _ = rec.drain()
        assert _same_schedule(full, again)


class TestSnapshotCompaction:
    """Journal snapshot/compaction: ``store.snapshot()`` folds the
    quiescent prefix into one record, and recovery from snapshot + tail
    is bit-identical to replaying the uncompacted journal."""

    def _driven(self, n=16, policy="sjf-bco", params=None, seed=3):
        cluster = philly_cluster(8, seed=1)
        jobs = _jobs(n, seed=seed)
        arrivals = _arrivals(len(jobs))
        svc = SchedulerService(cluster, policy=policy, params=params or {})
        _submit_all(svc, jobs, arrivals)
        while svc.step():
            pass
        return cluster, jobs, arrivals, svc

    @staticmethod
    def _same_daemon(a, b):
        assert np.array_equal(a.state.U, b.state.U)
        assert np.array_equal(a.state.R, b.state.R)
        assert a.state.est_finish == b.state.est_finish
        assert a.rounds == b.rounds and a.clock.now() == b.clock.now()
        assert sorted(a.records) == sorted(b.records)
        for jid, ra in a.records.items():
            rb = b.records[jid]
            assert ra.state is rb.state and ra.tenant == rb.tenant
            assert ra.rho == rb.rho and ra.start == rb.start
            assert ra.finish == rb.finish
            assert (ra.gpus is None) == (rb.gpus is None)
            if ra.gpus is not None:
                assert np.array_equal(ra.gpus, rb.gpus)

    def test_snapshot_recover_bit_identical(self):
        cluster, jobs, arrivals, svc = self._driven()
        store = svc.daemon.store
        compacted = store.prefix(len(store))
        saved = compacted.snapshot()
        assert saved > 0 and len(compacted) < len(store)
        kinds = [e.kind for e in compacted.entries()]
        assert kinds[:2] == ["cluster", "snapshot"]
        qm = lambda: QueueManager(TenantConfig("sjf-bco"))  # noqa: E731
        full = Daemon.recover(cluster, store.prefix(len(store)), qm())
        quick = Daemon.recover(cluster, compacted, qm())
        self._same_daemon(full, quick)
        sa, _ = full.drain()
        sb, _ = quick.drain()
        assert _same_schedule(sa, sb)

    def test_snapshot_every_prefix_identical(self):
        """Compact at EVERY journal prefix -- including cuts inside an
        open PLACING bracket, whose entries must stay in the tail -- and
        the recovered daemon still reproduces the full schedule."""
        cluster, jobs, arrivals, svc = self._driven(n=12)
        full, _ = svc.drain()
        store = svc.daemon.store
        mid_bracket = 0
        for k in range(len(store) + 1):
            snap = store.prefix(k)
            entries = snap.entries()
            open_bracket = any(e.kind == "transition"
                               and e.payload["to"] == "PLACING"
                               for e in entries) and \
                entries[-1].kind != "decided" if entries else False
            mid_bracket += bool(open_bracket)
            snap.snapshot()
            daemon = Daemon.recover(cluster, snap,
                                    QueueManager(TenantConfig("sjf-bco")))
            for j, a in list(zip(jobs, arrivals))[len(daemon.jobs):]:
                daemon.admit(j, int(a))
            sched, _ = daemon.drain()
            assert _same_schedule(full, sched), f"prefix {k}"
        assert mid_bracket > 0

    def test_snapshot_preserves_rng_state(self):
        cluster, jobs, arrivals, svc = self._driven(
            n=14, policy="rand", params={"seed": 11})
        store = svc.daemon.store
        compacted = store.prefix(len(store))
        assert compacted.snapshot() > 0
        snap_entry = compacted.entries()[1]
        assert snap_entry.payload["rng"]          # last generator state kept
        cfg = TenantConfig("rand", params=(("seed", 11),))
        full = Daemon.recover(cluster, store.prefix(len(store)),
                              QueueManager(cfg))
        quick = Daemon.recover(cluster, compacted, QueueManager(cfg))
        assert (full._choosers["default"].get_state()
                == quick._choosers["default"].get_state())
        self._same_daemon(full, quick)

    def test_resnapshot_composes(self):
        """snapshot -> write on -> snapshot again: the second fold seeds
        from the first record, and recovery stays exact."""
        cluster, jobs, arrivals, svc = self._driven(n=16)
        full, _ = svc.drain()
        store = svc.daemon.store
        half = store.prefix(len(store) // 2)
        assert half.snapshot() > 0
        daemon = Daemon.recover(cluster, half,
                                QueueManager(TenantConfig("sjf-bco")))
        for j, a in list(zip(jobs, arrivals))[len(daemon.jobs):]:
            daemon.admit(j, int(a))
        while daemon.step():
            pass
        assert daemon.store.snapshot() > 0        # re-fold snapshot + suffix
        kinds = [e.kind for e in daemon.store.entries()]
        assert kinds.count("snapshot") == 1
        again = Daemon.recover(cluster, daemon.store,
                               QueueManager(TenantConfig("sjf-bco")))
        sched, _ = again.drain()
        assert _same_schedule(full, sched)

    def test_sqlite_snapshot_survives_reopen(self, tmp_path):
        cluster, jobs, arrivals, svc = self._driven()
        mem = svc.daemon.store
        path = str(tmp_path / "compact.db")
        db = SqliteStore(path)
        for e in mem.entries():
            db.append(e.kind, e.jid, e.payload, ts=e.ts)
        rows = len(db)
        saved = db.snapshot()
        assert saved > 0 and len(db) == rows - saved
        db.close()
        back = SqliteStore(path)
        full = Daemon.recover(cluster, mem.prefix(len(mem)),
                              QueueManager(TenantConfig("sjf-bco")))
        quick = Daemon.recover(cluster, back, QueueManager(TenantConfig(
            "sjf-bco")))
        self._same_daemon(full, quick)
        # appends after compaction keep strictly increasing sequence
        e = back.append("advance", -1, {"t": 999.0})
        assert e.seq > back.entries()[-2].seq
        back.close()

    def test_memory_seq_persists_across_snapshot(self):
        cluster, jobs, arrivals, svc = self._driven(n=8)
        store = svc.daemon.store
        last_seq = store.entries()[-1].seq
        store.snapshot()
        e = store.append("advance", -1, {"t": 1.0})
        assert e.seq == last_seq + 1              # no reuse after the fold
