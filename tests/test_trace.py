"""Trace-replay arrivals: CSV parsing, the scenario-layer trace kinds,
and batch-vs-daemon replay identity on the bundled sample trace."""
import os

import numpy as np
import pytest

from repro.core import (ArrivalSpec, ClusterSpec, Scenario, WorkloadSpec,
                        load_trace, philly_cluster, replay_trace,
                        run_scenario)
from repro.core.trace import (_DEFAULT_BATCH, _DEFAULT_DT_BWD,
                              _DEFAULT_DT_FWD)
from repro.service import SchedulerService

SAMPLE = os.path.join(os.path.dirname(__file__), os.pardir, "examples",
                      "sample_trace.csv")


class TestLoadTrace:
    def test_sample_parses(self):
        jobs, arrivals = load_trace(SAMPLE)
        assert len(jobs) == 16
        assert [j.jid for j in jobs] == list(range(16))
        # plan_gpu is GPU-percent: 100 -> 1 device, 1600 -> 16.
        assert {j.num_gpus for j in jobs} == {1, 2, 4, 8, 16}
        assert arrivals.dtype == np.int64
        assert arrivals[0] == 0
        assert np.all(np.diff(arrivals) >= 0)     # sorted by start_time

    def test_optional_columns_default(self, tmp_path):
        p = tmp_path / "min.csv"
        p.write_text("start_time,plan_gpu,iterations,grad_size\n"
                     "5,200,1000,0.001\n"
                     "9,100,2000,0.002\n")
        jobs, arrivals = load_trace(str(p))
        assert jobs[0].batch == _DEFAULT_BATCH
        assert jobs[0].dt_fwd == _DEFAULT_DT_FWD
        assert jobs[0].dt_bwd == _DEFAULT_DT_BWD
        # The excerpt's epoch is shifted out: first arrival is slot 0.
        assert list(arrivals) == [0, 4]

    def test_empty_optional_cells_default(self):
        jobs, _ = load_trace(SAMPLE)
        # Row "7,100,1100,0.0006,,," has empty optional cells.
        j = next(j for j in jobs if j.iters == 1100)
        assert j.batch == _DEFAULT_BATCH
        assert j.dt_bwd == _DEFAULT_DT_BWD

    def test_ties_keep_file_order(self, tmp_path):
        p = tmp_path / "tie.csv"
        p.write_text("start_time,plan_gpu,iterations,grad_size\n"
                     "3,100,111,0.001\n"
                     "3,100,222,0.001\n")
        jobs, _ = load_trace(str(p))
        assert [j.iters for j in jobs] == [111, 222]

    def test_fractional_gpu_rounds_to_device(self, tmp_path):
        p = tmp_path / "frac.csv"
        p.write_text("start_time,plan_gpu,iterations,grad_size\n"
                     "0,25,100,0.001\n"
                     "0,250,100,0.001\n")
        jobs, _ = load_trace(str(p))
        assert [j.num_gpus for j in jobs] == [1, 2]

    def test_missing_column_loud(self, tmp_path):
        p = tmp_path / "bad.csv"
        p.write_text("start_time,plan_gpu,iterations\n0,100,100\n")
        with pytest.raises(ValueError, match="grad_size"):
            load_trace(str(p))

    def test_empty_trace_loud(self, tmp_path):
        p = tmp_path / "empty.csv"
        p.write_text("start_time,plan_gpu,iterations,grad_size\n")
        with pytest.raises(ValueError, match="no job rows"):
            load_trace(str(p))

    def test_unparseable_row_names_line(self, tmp_path):
        p = tmp_path / "garbled.csv"
        p.write_text("start_time,plan_gpu,iterations,grad_size\n"
                     "0,100,100,0.001\n"
                     "1,abc,100,0.001\n")
        with pytest.raises(ValueError, match="row 3"):
            load_trace(str(p))


class TestTraceScenario:
    def _scenario(self, **cluster_kw):
        return Scenario(
            cluster=ClusterSpec(num_servers=4, seed=2, **cluster_kw),
            workload=WorkloadSpec(kind="trace", path=SAMPLE),
            arrivals=ArrivalSpec(kind="trace", path=SAMPLE),
            policy="sjf-bco", horizon=10**6)

    def test_end_to_end(self):
        report = run_scenario(self._scenario())
        assert report.sim.completed == 16
        assert report.sim.makespan > 0

    def test_daemon_replay_matches_batch(self):
        """replay_trace through the service daemon == run_scenario on the
        same trace (the daemon's identity guarantee extends to traces)."""
        report = run_scenario(self._scenario())
        cluster = ClusterSpec(num_servers=4, seed=2).build()
        svc = SchedulerService(cluster, policy="sjf-bco")
        records = replay_trace(svc.daemon, SAMPLE)
        assert len(records) == 16
        sched, sim = svc.drain()
        assert len(sched.assignment) == len(report.schedule.assignment)
        for (j1, g1), (j2, g2) in zip(sched.assignment,
                                      report.schedule.assignment):
            assert j1 == j2
            assert np.array_equal(g1, g2)
        assert np.array_equal(sim.finish, report.sim.finish)
        assert sim.makespan == report.sim.makespan

    def test_trace_on_hetero_cluster(self):
        report = run_scenario(self._scenario(
            speed_tiers=((50.0, 0.5), (10.0, 0.5)),
            link_classes=((1.25, "shared", 0.5), (1.0, "isolated", 0.5))))
        assert report.scenario.cluster.build().is_heterogeneous
        assert report.sim.completed == 16

    def test_workload_truncation_renumbers(self):
        jobs = WorkloadSpec(kind="trace", path=SAMPLE, num_jobs=5).build()
        assert [j.jid for j in jobs] == list(range(5))
        arrivals = ArrivalSpec(kind="trace", path=SAMPLE).build(jobs)
        assert len(arrivals) == 5

    def test_arrival_count_mismatch_loud(self, tmp_path):
        p = tmp_path / "short.csv"
        p.write_text("start_time,plan_gpu,iterations,grad_size\n"
                     "0,100,100,0.001\n")
        jobs = WorkloadSpec(kind="trace", path=SAMPLE).build()
        with pytest.raises(ValueError, match="1 arrivals"):
            ArrivalSpec(kind="trace", path=str(p)).build(jobs)

    def test_paths_required(self):
        with pytest.raises(ValueError, match="path"):
            WorkloadSpec(kind="trace").build()
        with pytest.raises(ValueError, match="path"):
            ArrivalSpec(kind="trace").build([])


def test_replay_trace_rejects_bad_path():
    svc = SchedulerService(philly_cluster(2, seed=0), policy="sjf-bco")
    with pytest.raises(FileNotFoundError):
        replay_trace(svc.daemon, "/nonexistent/trace.csv")
