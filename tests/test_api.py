"""Tests for the unified scheduling API: registry, one-signature policies,
batch == zero-arrival equivalence, and the declarative Scenario layer."""
import dataclasses

import numpy as np
import pytest

from repro.core import (ClusterSpec, Scenario, ScheduleRequest,
                        ScheduleResult, SchedulingPolicy, WorkloadSpec,
                        get_policy, list_policies, philly_cluster,
                        philly_workload, register_policy, run_scenario,
                        simulate)

BUILTIN = {"sjf-bco", "ff", "ls", "rand", "reserved", "sjf-bco-adaptive"}


def _small_instance(n_servers=6, n_jobs=24, seed=1):
    cluster = philly_cluster(n_servers, seed=seed)
    jobs = philly_workload(seed=seed)[:n_jobs]
    jobs = [dataclasses.replace(j, jid=i) for i, j in enumerate(jobs)]
    return cluster, jobs


class TestRegistry:
    def test_builtins_registered(self):
        assert BUILTIN <= set(list_policies())

    def test_get_policy_round_trip(self):
        for name in BUILTIN:
            policy = get_policy(name)
            assert callable(policy)
            assert isinstance(policy, SchedulingPolicy)

    def test_unknown_policy_raises_with_listing(self):
        with pytest.raises(KeyError, match="sjf-bco"):
            get_policy("no-such-policy")

    def test_case_insensitive_lookup(self):
        assert get_policy("SJF-BCO") is get_policy("sjf-bco")

    def test_custom_policy_registration(self):
        @register_policy("test-only-greedy")
        def greedy(request: ScheduleRequest) -> ScheduleResult:
            return get_policy("ls")(request)

        try:
            assert "test-only-greedy" in list_policies()
            cluster, jobs = _small_instance()
            sched = get_policy("test-only-greedy")(
                ScheduleRequest(cluster=cluster, jobs=jobs, horizon=1200))
            assert len(sched.assignment) == len(jobs)
        finally:
            from repro.core import api
            api._REGISTRY.pop("test-only-greedy", None)

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            @register_policy("sjf-bco")
            def imposter(request):                     # pragma: no cover
                raise AssertionError


class TestUnifiedSignature:
    def test_every_policy_runs_through_one_signature(self):
        cluster, jobs = _small_instance()
        request = ScheduleRequest(cluster=cluster, jobs=jobs, horizon=1200)
        for name in BUILTIN:
            sched = get_policy(name)(request)
            assert isinstance(sched, ScheduleResult), name
            assert {j for j, _ in sched.assignment} == set(range(len(jobs))), name
            sim = simulate(cluster, jobs, sched.assignment)
            assert sim.completed == len(jobs), name

    def test_request_validates_arrivals_shape(self):
        cluster, jobs = _small_instance()
        with pytest.raises(ValueError, match="arrivals"):
            ScheduleRequest(cluster=cluster, jobs=jobs,
                            arrivals=np.zeros(3, dtype=np.int64))

    def test_batch_equals_all_zero_arrivals(self):
        """Batch scheduling is the arrivals=None special case: an all-zero
        arrival vector must produce the identical schedule."""
        cluster, jobs = _small_instance()
        zeros = np.zeros(len(jobs), dtype=np.int64)
        for name in ("sjf-bco", "ff", "ls", "rand"):
            batch = get_policy(name)(
                ScheduleRequest(cluster=cluster, jobs=jobs, horizon=1200))
            online = get_policy(name)(
                ScheduleRequest(cluster=cluster, jobs=jobs, arrivals=zeros,
                                horizon=1200))
            assert len(batch.assignment) == len(online.assignment), name
            for (ja, ga), (jb, gb) in zip(batch.assignment, online.assignment):
                assert ja == jb, name
                assert np.array_equal(ga, gb), name

    def test_params_reach_the_policy(self):
        cluster, jobs = _small_instance()
        fixed = get_policy("sjf-bco")(
            ScheduleRequest(cluster=cluster, jobs=jobs, horizon=1200,
                            params={"kappas": [4]}))
        assert fixed.kappa == 4


class TestScenario:
    def test_run_scenario_smoke_sjf_beats_rand(self):
        """Fig. 4 ranking on a small Philly cluster: SJF-BCO's simulated
        makespan is no worse than RAND's."""
        base = dict(cluster=ClusterSpec(num_servers=6, seed=1),
                    workload=WorkloadSpec(num_jobs=24, seed=1),
                    horizon=1200)
        sjf = run_scenario(Scenario(policy="sjf-bco", **base))
        rand = run_scenario(Scenario(policy="rand", **base))
        assert sjf.sim.completed == 24
        assert sjf.makespan <= rand.makespan
        assert sjf.contention.peak <= rand.contention.peak

    def test_scenario_is_reproducible(self):
        sc = Scenario(cluster=ClusterSpec(num_servers=4, seed=2),
                      workload=WorkloadSpec(num_jobs=12, seed=2),
                      policy="rand", policy_params=(("seed", 7),),
                      horizon=2400)
        a, b = run_scenario(sc), run_scenario(sc)
        assert a.makespan == b.makespan
        assert np.array_equal(a.sim.finish, b.sim.finish)

    def test_online_scenario(self):
        from repro.core import ArrivalSpec
        rep = run_scenario(Scenario(
            cluster=ClusterSpec(num_servers=6, seed=1),
            workload=WorkloadSpec(num_jobs=24, seed=1),
            arrivals=ArrivalSpec(kind="poisson", rate=0.5, seed=1),
            policy="sjf-bco", horizon=10**6))
        assert rep.sim.completed == 24
        arrivals = rep.scenario.arrivals.build(
            rep.scenario.workload.build())
        assert np.all(rep.sim.start >= arrivals)

    def test_pareto_arrivals_seeded_and_bursty(self):
        """Heavy-tailed arrivals: seeded (reproducible), mean-normalised
        to ``rate``, and burstier than Poisson at the same rate (higher
        squared coefficient of variation of the gaps)."""
        from repro.core import ArrivalSpec
        jobs = WorkloadSpec(num_jobs=2000, seed=5).build()
        spec = ArrivalSpec(kind="pareto", rate=0.5, seed=9, shape=1.5)
        a, b = spec.build(jobs), spec.build(jobs)
        assert np.array_equal(a, b)                     # seeded
        assert np.all(np.diff(a) >= 0)                  # nondecreasing
        gaps = np.diff(a.astype(np.float64))
        pois = np.diff(ArrivalSpec(kind="poisson", rate=0.5,
                                   seed=9).build(jobs).astype(np.float64))
        # long-run rate lands near the requested one ...
        assert 0.2 <= len(jobs) / max(a[-1], 1) <= 1.5
        # ... but the gap distribution is heavier-tailed than Poisson
        cv2 = gaps.var() / max(gaps.mean(), 1e-12) ** 2
        cv2_pois = pois.var() / max(pois.mean(), 1e-12) ** 2
        assert cv2 > cv2_pois
        with pytest.raises(ValueError, match="shape > 1"):
            ArrivalSpec(kind="pareto", shape=1.0).build(jobs)

    def test_pareto_scenario_end_to_end(self):
        from repro.core import ArrivalSpec
        rep = run_scenario(Scenario(
            cluster=ClusterSpec(num_servers=6, seed=1),
            workload=WorkloadSpec(num_jobs=24, seed=1),
            arrivals=ArrivalSpec(kind="pareto", rate=0.5, seed=1),
            policy="sjf-bco", horizon=10**6))
        assert rep.sim.completed == 24
        arrivals = rep.scenario.arrivals.build(
            rep.scenario.workload.build())
        assert np.all(rep.sim.start >= arrivals)

    def test_contention_stats_consistent(self):
        rep = run_scenario(Scenario(
            cluster=ClusterSpec(num_servers=4, seed=3),
            workload=WorkloadSpec(num_jobs=16, seed=3),
            policy="ls", horizon=2400))
        assert rep.contention.peak == rep.sim.peak_contention
        assert 0.0 <= rep.contention.contended_frac <= 1.0
        assert rep.contention.mean <= rep.contention.peak
