"""Heterogeneity-aware cluster model: degenerate bit-identity + directed
behaviour tests.

Two obligations from the per-GPU-speed / per-link-class refactor:

  * **Degenerate identity** -- a cluster whose ``gpu_speeds`` / ``links``
    arrays merely restate the homogeneous scalars must produce
    bit-identical results to the scalar cluster across every oracle axis:
    engines (incremental / batched / reference), sweep and bisect modes,
    placement engines (scalar / columnar), simulator readiness and
    stepping modes, and online arrivals.
  * **Directed heterogeneity** -- a genuinely mixed cluster must *change*
    behaviour the way Eqs. (1) and (6)-(8) say: a slow GPU tier flips
    SJF-BCO's placement away from the slow server, and an ``isolated``
    uplink drops the Eq. (8) sharing divisor ``f(alpha, k)``.

A hypothesis property sweep runs when hypothesis is installed (the CI
image may not ship it; the seeded numpy sweeps cover the same space
deterministically either way).
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.core import (Cluster, ClusterSpec, Job, ScheduleRequest,
                        evaluate, evaluate_many, get_policy, philly_cluster,
                        philly_workload, simulate, tau_bounds)
from repro.core.contention import IncrementalEval

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                 # pragma: no cover
    HAVE_HYPOTHESIS = False


def _uniform_hetero(cluster):
    """Restate a scalar cluster's constants as per-device arrays."""
    return dataclasses.replace(
        cluster,
        gpu_speeds=(cluster.gpu_speed,) * cluster.num_gpus,
        links=((cluster.b_inter, "shared"),) * cluster.num_servers)


def _philly_case(seed, n_jobs=42, n_servers=8):
    cluster = philly_cluster(n_servers, seed=seed)
    mix = ((1, n_jobs // 3), (2, n_jobs // 6), (4, n_jobs // 4),
           (8, n_jobs // 6), (16, n_jobs // 12))
    jobs = philly_workload(seed=seed, mix=mix)
    return cluster, jobs


def _hetero_case(seed, n_jobs=24, n_servers=6):
    """A genuinely mixed cluster (two speed tiers, mixed link classes)."""
    base = philly_cluster(n_servers, seed=seed)
    rng = np.random.default_rng(1000 + seed)
    speeds = []
    for cap in base.capacities:
        tier = float(rng.choice([base.gpu_speed, base.gpu_speed * 0.25]))
        speeds += [tier] * cap
    links = tuple(
        (float(rng.choice([base.b_inter, base.b_inter * 0.5])),
         str(rng.choice(["shared", "isolated"])))
        for _ in range(base.num_servers))
    cluster = dataclasses.replace(base, gpu_speeds=tuple(speeds),
                                  links=links)
    assert cluster.is_heterogeneous
    mix = ((1, n_jobs // 3), (2, n_jobs // 4), (4, n_jobs // 4),
           (8, n_jobs // 6))
    return cluster, philly_workload(seed=seed, mix=mix)


def _random_stack(cluster, jobs, rng, n_cands=5):
    S = cluster.num_servers
    stack = np.zeros((n_cands, len(jobs), S), dtype=np.int64)
    for c in range(n_cands):
        for i, job in enumerate(jobs):
            for _ in range(job.num_gpus):
                stack[c, i, rng.integers(S)] += 1
    return stack


def _assert_schedules_equal(a, b):
    assert a.theta == b.theta
    assert a.kappa == b.kappa
    assert a.est_makespan == b.est_makespan
    assert a.max_busy_time == b.max_busy_time
    assert len(a.assignment) == len(b.assignment)
    for (j1, g1), (j2, g2) in zip(a.assignment, b.assignment):
        assert j1 == j2
        assert np.array_equal(g1, g2)
    assert np.array_equal(a.est_start, b.est_start)
    assert np.array_equal(a.est_finish, b.est_finish)


def _assert_sims_equal(a, b):
    assert a.events == b.events
    assert np.array_equal(a.start, b.start)
    assert np.array_equal(a.finish, b.finish)
    assert a.makespan == b.makespan
    assert a.peak_contention == b.peak_contention


class TestClusterSurface:
    def test_uniform_arrays_are_degenerate(self):
        cluster = philly_cluster(4, seed=0)
        assert not cluster.is_heterogeneous
        assert not _uniform_hetero(cluster).is_heterogeneous

    def test_mixed_arrays_are_heterogeneous(self):
        cluster = philly_cluster(2, seed=0)
        speeds = list(_uniform_hetero(cluster).gpu_speeds)
        speeds[0] *= 0.5
        assert dataclasses.replace(
            cluster, gpu_speeds=tuple(speeds)).is_heterogeneous
        # An isolated link at the nominal bandwidth is still heterogeneous:
        # the class changes Eq. (8) even when the number doesn't.
        links = ((cluster.b_inter, "isolated"),) \
            + ((cluster.b_inter, "shared"),) * (cluster.num_servers - 1)
        assert dataclasses.replace(cluster, links=links).is_heterogeneous

    def test_derived_arrays(self):
        cluster = Cluster((2, 3), gpu_speeds=(50.0, 40.0, 50.0, 50.0, 10.0),
                          links=((1.25, "shared"), (0.5, "isolated")))
        assert np.array_equal(cluster.server_speed_floor, [40.0, 10.0])
        assert np.array_equal(cluster.uplink_bandwidth, [1.25, 0.5])
        assert np.array_equal(cluster.uplink_isolated, [False, True])
        assert np.array_equal(cluster.uplink_shared_or_inf, [1.25, np.inf])
        assert np.array_equal(cluster.uplink_isolated_or_inf, [np.inf, 0.5])

    @pytest.mark.parametrize("kwargs,match", [
        (dict(gpu_speeds=(50.0,)), "one speed per GPU"),
        (dict(gpu_speeds=50.0), "per-GPU"),
        (dict(gpu_speeds=(50.0, 50.0, 50.0, -1.0)), "positive"),
        (dict(links=((1.25, "shared"),)), "one uplink per server"),
        (dict(links=((1.25, "dedicated"), (1.25, "shared"))), "kind"),
        (dict(links=((0.0, "shared"), (1.25, "shared"))), "positive"),
        (dict(links=((500.0, "shared"), (1.25, "shared"))), "b_intra"),
        (dict(gpu_speed=(50.0, 50.0, 50.0, 50.0)), "gpu_speeds"),
        (dict(b_inter=(1.25, 1.25)), "links"),
    ])
    def test_loud_validation(self, kwargs, match):
        with pytest.raises((ValueError, TypeError), match=match):
            Cluster((2, 2), **kwargs)

    def test_payload_roundtrip(self):
        cluster, _ = _hetero_case(0)
        payload = json.loads(json.dumps(cluster.to_payload()))
        assert Cluster.from_payload(payload) == cluster
        scalar = philly_cluster(3, seed=1)
        assert Cluster.from_payload(
            json.loads(json.dumps(scalar.to_payload()))) == scalar

    def test_cluster_spec_draws_tiers(self):
        spec = ClusterSpec(num_servers=5, seed=3,
                           speed_tiers=((50.0, 0.5), (12.5, 0.5)),
                           link_classes=((1.25, "shared", 0.5),
                                         (1.25, "isolated", 0.5)))
        cluster = spec.build()
        assert cluster.is_heterogeneous
        assert set(cluster.gpu_speeds) <= {50.0, 12.5}
        # The capacity draw precedes the tier draws: same seed, same shape.
        assert cluster.capacities == philly_cluster(5, seed=3).capacities
        # A single tier restating the scalar is degenerate.
        assert not ClusterSpec(num_servers=5, seed=3,
                               speed_tiers=((50.0, 1.0),)).build() \
            .is_heterogeneous

    def test_unknown_override_rejected(self):
        spec = ClusterSpec(num_servers=2, overrides=(("gpu_speedz", 1.0),))
        with pytest.raises(ValueError, match="gpu_speedz.*speed_tiers"):
            spec.build()


class TestDegenerateIdentity:
    """Uniform hetero arrays == homogeneous scalars, bit for bit."""

    @pytest.mark.parametrize("policy", ["sjf-bco", "ff", "ls"])
    @pytest.mark.parametrize("seed", range(2))
    def test_policies(self, policy, seed):
        cluster, jobs = _philly_case(seed)
        a = get_policy(policy)(ScheduleRequest(cluster=cluster, jobs=jobs,
                                               horizon=2400))
        b = get_policy(policy)(ScheduleRequest(
            cluster=_uniform_hetero(cluster), jobs=jobs, horizon=2400))
        _assert_schedules_equal(a, b)

    @pytest.mark.parametrize("params", [
        {"engine": "incremental"},
        {"engine": "batched"},
        {"engine": "reference"},
        {"sweep": "sequential"},
        {"bisect": "sequential"},
        {"placement": "columnar"},
    ])
    def test_oracle_axes(self, params):
        cluster, jobs = _philly_case(1)
        a = get_policy("sjf-bco")(ScheduleRequest(
            cluster=cluster, jobs=jobs, horizon=2400, params=params))
        b = get_policy("sjf-bco")(ScheduleRequest(
            cluster=_uniform_hetero(cluster), jobs=jobs, horizon=2400,
            params=params))
        _assert_schedules_equal(a, b)

    @pytest.mark.parametrize("readiness,stepping,engine", [
        ("tracked", "multi", "incremental"),
        ("tracked", "single", "incremental"),
        ("rescan", None, "incremental"),
        ("tracked", None, "reference"),
    ])
    def test_simulator_axes(self, readiness, stepping, engine):
        cluster, jobs = _philly_case(2)
        uniform = _uniform_hetero(cluster)
        sched = get_policy("sjf-bco")(ScheduleRequest(cluster=cluster,
                                                      jobs=jobs,
                                                      horizon=2400))
        a = simulate(cluster, jobs, sched.assignment, engine=engine,
                     readiness=readiness, stepping=stepping)
        b = simulate(uniform, jobs, sched.assignment, engine=engine,
                     readiness=readiness, stepping=stepping)
        _assert_sims_equal(a, b)

    def test_online_arrivals(self):
        cluster, jobs = _philly_case(3, n_jobs=30)
        rng = np.random.default_rng(7)
        arrivals = rng.integers(0, 300, size=len(jobs)).astype(np.int64)
        req = dict(jobs=jobs, arrivals=arrivals, horizon=10**6)
        a = get_policy("sjf-bco")(ScheduleRequest(cluster=cluster, **req))
        b = get_policy("sjf-bco")(ScheduleRequest(
            cluster=_uniform_hetero(cluster), **req))
        _assert_schedules_equal(a, b)
        _assert_sims_equal(
            simulate(cluster, jobs, a.assignment, arrivals=arrivals),
            simulate(_uniform_hetero(cluster), jobs, b.assignment,
                     arrivals=arrivals))

    def test_engine_values_identical(self):
        cluster, jobs = _philly_case(4, n_jobs=18)
        uniform = _uniform_hetero(cluster)
        stack = _random_stack(cluster, jobs, np.random.default_rng(4))
        a, b = evaluate_many(cluster, jobs, stack), \
            evaluate_many(uniform, jobs, stack)
        assert np.array_equal(a.tau, b.tau)
        assert np.array_equal(a.bandwidth, b.bandwidth)
        assert np.array_equal(a.reduce, b.reduce)
        for job in jobs:
            assert tau_bounds(cluster, job) == tau_bounds(uniform, job)


def _engine_agreement(seed):
    """evaluate == evaluate_many == IncrementalEval on a mixed cluster."""
    cluster, jobs = _hetero_case(seed)
    rng = np.random.default_rng(seed)
    stack = _random_stack(cluster, jobs, rng)
    many = evaluate_many(cluster, jobs, stack)
    for c in range(stack.shape[0]):
        ref = evaluate(cluster, jobs, stack[c])
        assert np.array_equal(ref.tau, many.tau[c])
        assert np.array_equal(ref.bandwidth, many.bandwidth[c])
        inc = IncrementalEval(cluster)
        rows = [inc.add(job, stack[c, i]) for i, job in enumerate(jobs)]
        for i, r in enumerate(rows):
            assert inc.tau_of(r) == ref.tau[i]
        # Probes agree with committed rows.
        probe = inc.probe_tau_many(jobs[0], stack[:, 0, :])
        assert probe.shape == (stack.shape[0],)
    # tau_bounds brackets every realised tau on the mixed cluster.
    for i, job in enumerate(jobs):
        lo, hi = tau_bounds(cluster, job)
        assert float(many.tau[:, i].min()) >= lo
        assert float(many.tau[:, i].max()) <= hi


class TestHeteroEngineAgreement:
    if HAVE_HYPOTHESIS:
        @given(st.integers(0, 2**31 - 1))
        @settings(max_examples=10, deadline=None)
        def test_engines_agree(self, seed):
            _engine_agreement(seed)
    else:
        @pytest.mark.parametrize("seed", [0, 1, 7, 23, 2**31 - 1])
        def test_engines_agree(self, seed):
            _engine_agreement(seed)

    def test_probe_matches_fresh_evaluate(self):
        """Hetero probes (scalar_tau fast path) == committing the row."""
        cluster, jobs = _hetero_case(2)
        rng = np.random.default_rng(2)
        placed = _random_stack(cluster, jobs[1:], rng, n_cands=1)[0]
        inc = IncrementalEval(cluster)
        for i, job in enumerate(jobs[1:]):
            inc.add(job, placed[i])
        cands = _random_stack(cluster, [jobs[0]], rng, n_cands=6)[:, 0, :]
        taus = inc.probe_tau_many(jobs[0], cands)
        for c in range(cands.shape[0]):
            assert taus[c] == inc.probe_tau(jobs[0], cands[c])

    def test_kernel_backend_agrees_x64(self):
        import jax
        from repro.core.contention import tau_backend
        x64_was = jax.config.jax_enable_x64
        jax.config.update("jax_enable_x64", True)
        try:
            cluster, jobs = _hetero_case(3, n_jobs=12)
            stack = _random_stack(cluster, jobs, np.random.default_rng(3))
            ref = evaluate_many(cluster, jobs, stack)
            with tau_backend("kernel"):
                kern = evaluate_many(cluster, jobs, stack)
            assert np.array_equal(ref.p, kern.p)
            assert np.array_equal(ref.tau, kern.tau)
            assert np.array_equal(ref.phi, kern.phi)
        finally:
            jax.config.update("jax_enable_x64", x64_was)


class TestDirectedHetero:
    """Mixed clusters must change behaviour the way the model says."""

    def _straddle_case(self, links):
        cluster = Cluster((2, 2), links=links)
        jobs = [Job(jid=j, num_gpus=2, iters=3000, grad_size=1.5e-3,
                    batch=32, dt_fwd=3e-4, dt_bwd=8e-3) for j in range(2)]
        Y = np.array([[1, 1], [1, 1]], dtype=np.int64)   # both straddle
        return cluster, jobs, Y

    def test_isolated_uplink_drops_divisor(self):
        shared = ((1.25, "shared"), (1.25, "shared"))
        isolated = ((1.25, "isolated"), (1.25, "isolated"))
        cl_sh, jobs, Y = self._straddle_case(shared)
        cl_iso, _, _ = self._straddle_case(isolated)
        m_sh, m_iso = evaluate(cl_sh, jobs, Y), evaluate(cl_iso, jobs, Y)
        # Both jobs straddle both servers: p = 2, so f(alpha, k) > 1.
        assert np.array_equal(m_sh.p, [2, 2])
        k = max(cl_sh.xi1 * 2.0, 1.0)
        f = k + cl_sh.alpha * (k - 1.0)
        assert f > 1.0
        share = (jobs[0].grad_size / 2.0) * 1.0
        compute = jobs[0].dt_fwd * jobs[0].batch + jobs[0].dt_bwd
        # Shared uplinks pay the divisor; isolated uplinks do not (Eq. 8).
        assert np.array_equal(m_sh.bandwidth, [1.25 / f, 1.25 / f])
        assert np.array_equal(m_iso.bandwidth, [1.25, 1.25])
        expect_iso = 2.0 * share / 1.25 + share / cl_iso.gpu_speed \
            + cl_iso.xi2 * 2.0 + compute
        assert m_iso.tau[0] == expect_iso
        assert m_iso.tau[0] < m_sh.tau[0]

    def test_mixed_links_take_min(self):
        # One isolated uplink slower than shared/f: the isolated pipe caps.
        f_links = ((0.2, "isolated"), (1.25, "shared"))
        cluster, jobs, Y = self._straddle_case(f_links)
        model = evaluate(cluster, jobs, Y)
        k = max(cluster.xi1 * 2.0, 1.0)
        f = k + cluster.alpha * (k - 1.0)
        assert np.array_equal(model.bandwidth,
                              [min(0.2, 1.25 / f)] * 2)

    def test_slow_server_governs_reduce(self):
        cluster = Cluster((2, 2), gpu_speeds=(50.0, 50.0, 5.0, 5.0))
        job = Job(jid=0, num_gpus=2, iters=1000, grad_size=2e-3, batch=32,
                  dt_fwd=3e-4, dt_bwd=8e-3)
        fast = evaluate(cluster, [job], np.array([[2, 0]]))
        straddle = evaluate(cluster, [job], np.array([[1, 1]]))
        share = job.grad_size / 2.0
        assert fast.reduce[0] == share / 50.0
        assert straddle.reduce[0] == share / 5.0      # slowest member

    def test_slow_tier_flips_sjf_bco_placement(self):
        """A 20x-slower server visibly changes SJF-BCO's picks: the
        speed-aware schedule loads the fast server harder."""
        rng = np.random.default_rng(0)
        homog = Cluster((4, 4))
        slow = dataclasses.replace(
            homog,
            gpu_speeds=(homog.gpu_speed,) * 4
            + (homog.gpu_speed * 0.05,) * 4)
        jobs = [Job(jid=j, num_gpus=2,
                    iters=int(rng.integers(2000, 6000)),
                    grad_size=float(rng.uniform(1.5e-3, 2.0e-3)),
                    batch=int(rng.integers(16, 64)),
                    dt_fwd=float(rng.uniform(2e-4, 5e-4)),
                    dt_bwd=float(rng.uniform(4e-3, 1.2e-2)))
                for j in range(6)]
        sh = get_policy("sjf-bco")(ScheduleRequest(cluster=homog, jobs=jobs,
                                                   horizon=10**6))
        ss = get_policy("sjf-bco")(ScheduleRequest(cluster=slow, jobs=jobs,
                                                   horizon=10**6))
        counts = {}
        for name, cl, sched in (("homog", homog, sh), ("slow", slow, ss)):
            per = np.zeros(2, dtype=int)
            for _, gpus in sched.assignment:
                for g in gpus:
                    per[0 if g < 4 else 1] += 1
            counts[name] = per
        assert not np.array_equal(counts["homog"], counts["slow"])
        # Speed-aware placement shifts GPU-slots toward the fast server.
        assert counts["slow"][0] > counts["slow"][1]
        assert counts["slow"][1] < counts["homog"][1]

    def test_columnar_matches_scalar_on_hetero(self):
        cluster, jobs = _hetero_case(5, n_jobs=16)
        a = get_policy("sjf-bco")(ScheduleRequest(
            cluster=cluster, jobs=jobs, horizon=2400,
            params={"placement": "scalar"}))
        b = get_policy("sjf-bco")(ScheduleRequest(
            cluster=cluster, jobs=jobs, horizon=2400,
            params={"placement": "columnar"}))
        _assert_schedules_equal(a, b)


class TestHeteroService:
    def test_journal_recovers_hetero_cluster(self):
        from repro.service import (Daemon, QueueManager, SchedulerService,
                                   SubmitRequest, TenantConfig)

        cluster, jobs = _hetero_case(6, n_jobs=12)
        svc = SchedulerService(cluster, policy="sjf-bco")
        for i, job in enumerate(jobs):
            svc.submit(SubmitRequest(job, arrival=2 * i))
        while svc.step():
            pass
        live = svc.daemon
        # The journal's first record is the cluster itself...
        first = live.store.entries()[0]
        assert first.kind == "cluster"
        # ...so recovery needs no out-of-band cluster object.
        recovered = Daemon.recover(None, live.store,
                                   QueueManager(TenantConfig("sjf-bco")))
        assert recovered.cluster == cluster
        assert recovered.cluster.is_heterogeneous
        assert np.array_equal(live.state.U, recovered.state.U)

    def test_recover_rejects_mismatched_cluster(self):
        from repro.service import (Daemon, QueueManager, SchedulerService,
                                   TenantConfig)

        cluster, _ = _hetero_case(7, n_jobs=4)
        svc = SchedulerService(cluster, policy="sjf-bco")
        other = philly_cluster(2, seed=9)
        with pytest.raises(ValueError, match="cluster"):
            Daemon.recover(other, svc.daemon.store,
                           QueueManager(TenantConfig("sjf-bco")))
