"""Preemption demo: a long job yields its ring to two short arrivals.

A 2-server cluster runs one 8-GPU job with a long residual (jid 0).  Two
short 2-GPU jobs arrive while it runs.  Under plain SJF-BCO the paper's
Eq. (3) forbids touching a running gang, so the short jobs queue behind
the monster.  The ``sjf-bco-dynamic`` chooser instead *evicts* jid 0
(checkpointing its residual work via :func:`repro.core.preempt.evict`),
places the short arrival first, then re-places the residual -- the short
jobs jump the queue, the long job resumes where it left off, and the
whole decision lands in the daemon's journal as one atomic
PLACING..decided bracket (EVICT records included), so a crashed daemon
replays it exactly.

The demo prints the journal's preemption records, the segmented schedule
(jid 0 appears once per resume), and the average-JCT win over the
non-preemptive baseline.

Run:  PYTHONPATH=src python examples/preempt_demo.py
"""
import numpy as np

from repro.core import Cluster, Job, ScheduleRequest, get_policy, simulate
from repro.service import Daemon, QueueManager, TenantConfig

cluster = Cluster(capacities=(4, 4))
long_job = Job(jid=0, num_gpus=8, iters=4000, grad_size=0.25, batch=32,
               dt_fwd=3e-4, dt_bwd=8e-3)
shorts = [Job(jid=i, num_gpus=2, iters=200, grad_size=0.05, batch=32,
              dt_fwd=3e-4, dt_bwd=8e-3) for i in (1, 2)]
jobs = [long_job, *shorts]
arrivals = [0, 5, 6]

# -- preemptive daemon: the short arrivals evict the long job --------------
daemon = Daemon(cluster, horizon=10**6,
                queue=QueueManager(TenantConfig(policy="sjf-bco-dynamic")))
for job, arrival in zip(jobs, arrivals):
    daemon.admit(job, arrival)
schedule, sim = daemon.drain()

evictions = [e for e in daemon.store.entries()
             if e.kind in ("evict", "resize")]
print(f"journal: {len(evictions)} eviction record(s)")
for e in evictions:
    print(f"  seq {e.seq:2d}  {e.kind} jid={e.jid} at t={e.payload['t']:.2f}"
          f"  residual iters={e.payload['iters']:.0f}")

print("\nsegmented schedule (jid 0 resumes once per eviction):")
for seg, ((jid, gpus), quota) in enumerate(zip(schedule.assignment,
                                               schedule.quotas)):
    print(f"  seg {seg}: jid {jid} on GPUs {gpus.tolist()} "
          f"({quota:.0f} iters)")

# -- baseline: plain SJF-BCO must make the shorts wait ---------------------
request = ScheduleRequest(cluster=cluster, jobs=jobs,
                          arrivals=np.asarray(arrivals, dtype=np.int64),
                          horizon=10**6)
base = get_policy("sjf-bco")(request)
base_sim = simulate(cluster, jobs, base.assignment,
                    arrivals=np.asarray(arrivals, dtype=np.int64))

print(f"\navg JCT: {sim.avg_jct:.1f} preemptive "
      f"vs {base_sim.avg_jct:.1f} non-preemptive "
      f"({base_sim.avg_jct - sim.avg_jct:+.1f} slots saved; "
      f"makespan {sim.makespan:.0f} vs {base_sim.makespan:.0f})")
assert sim.avg_jct < base_sim.avg_jct
assert sim.completed == base_sim.completed == len(jobs)

# -- the journal replays the whole decision atomically ---------------------
twin = Daemon.recover(cluster, daemon.store, horizon=10**6,
                      queue=QueueManager(TenantConfig(policy="sjf-bco-dynamic")))
assert np.array_equal(np.asarray(twin.state.seg_quota),
                      np.asarray(daemon.state.seg_quota))
print("\nrecovered twin daemon replays the eviction bracket bit-for-bit")
