"""End-to-end driver (deliverable b): schedule a queue of real training
jobs with SJF-BCO and EXECUTE each on its assigned device slice with the
explicit ring-all-reduce collective — then train the quickstart model for
a few hundred steps to show convergence.

This is `repro.launch.sched_launch` exercised as a library plus a longer
single-job training run.

Run:  PYTHONPATH=src python examples/rar_cluster_training.py
(uses 4 forced host devices; takes a few minutes on CPU)
"""
import os

# CPU-runnable: force 4 host devices so the ring collectives are real.
# Appends to (rather than clobbers) any XLA_FLAGS already in the env.
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4"
                               ).strip()

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import Cluster, Job, ScheduleRequest, get_policy, simulate

try:
    from repro.dist.steps import make_rar_train_step
except ImportError:
    raise SystemExit("rar_cluster_training needs the repro.dist training "
                     "substrate (see docs/ARCHITECTURE.md §repro.dist)")
from repro.configs import get_config
from repro.data import DataConfig, make_batch
from repro.models import build_model
from repro.models.config import InputShape
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig

# ---- 1) a small multi-tenant cluster: 2 servers x 2 GPUs ------------------
cluster = Cluster(capacities=(2, 2))
queue = [
    ("llama3.2-1b", 2), ("whisper-tiny", 1), ("internvl2-1b", 2),
]
jobs = [Job(jid=i, num_gpus=g, iters=1500, grad_size=1e-3, batch=32,
            dt_fwd=3e-4, dt_bwd=8e-3) for i, (_, g) in enumerate(queue)]
sched = get_policy("sjf-bco")(
    ScheduleRequest(cluster=cluster, jobs=jobs, horizon=50000))
sim = simulate(cluster, jobs, sched.assignment)
print(f"[cluster] SJF-BCO makespan {sim.makespan:.0f} slots, "
      f"peak contention {sim.peak_contention}")

# ---- 2) execute every job on its assigned slice with explicit RAR --------
devices = np.asarray(jax.devices())
for j, gpu_ids in sched.assignment:
    arch, w = queue[j]
    cfg = get_config(arch).reduced()
    mesh = Mesh(devices[np.asarray(gpu_ids)], ("data",))
    model = build_model(cfg, max_seq=64)
    params = model.init(jax.random.PRNGKey(j))
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=3)
    opt = adamw.init(ocfg, params)
    step = make_rar_train_step(model, ocfg, mesh)
    shape = InputShape("ex", 64, max(2, len(gpu_ids)), "train")
    for s in range(3):
        batch = jax.tree.map(jnp.asarray, make_batch(cfg, shape, s,
                                                     DataConfig(seed=j)))
        params, opt, m = step(params, opt, batch)
    print(f"[job {j}] {arch:14s} ring w={len(gpu_ids)} on devices "
          f"{list(map(int, gpu_ids))}: loss {float(m['loss']):.3f} OK")

# ---- 3) a longer convergence run (a few hundred steps) -------------------
print("[long-run] llama3.2-1b reduced, 150 steps, RAR over 4 devices")
cfg = get_config("llama3.2-1b").reduced()
mesh = Mesh(devices, ("data",))
model = build_model(cfg, max_seq=64)
params = model.init(jax.random.PRNGKey(0))
ocfg = AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=150)
opt = adamw.init(ocfg, params)
step = make_rar_train_step(model, ocfg, mesh)
shape = InputShape("long", 64, 8, "train")
losses = []
for s in range(150):
    batch = jax.tree.map(jnp.asarray, make_batch(cfg, shape, s))
    params, opt, m = step(params, opt, batch)
    losses.append(float(m["loss"]))
    if s % 50 == 0 or s == 149:
        print(f"  step {s:3d} loss {losses[-1]:.4f}")
first, last = np.mean(losses[:20]), np.mean(losses[-20:])
print(f"[long-run] mean loss {first:.3f} -> {last:.3f}")
assert last < first - 0.5, "expected clear convergence over 300 steps"
print("rar_cluster_training OK")
