"""Scheduler-service demo: a daemon's whole operational story in a page.

1. Start a :class:`~repro.service.SchedulerService` with a durable sqlite
   journal and submit a small Philly-mix stream (two tenants: "prod" on
   SJF-BCO, "batch" on FF).
2. Cancel one job while it is still queued.
3. Kill the daemon mid-stream (drop the object, journal survives on disk),
   recover a fresh one by replaying the journal, submit the rest.
4. Drain and print the recovered state table -- every placement made
   before the crash is preserved bit-for-bit, and the final schedule
   matches what an uninterrupted daemon (or a one-shot
   ``get_policy(...)(ScheduleRequest(...))`` call) would have produced.

Run:  PYTHONPATH=src python examples/service_demo.py
"""
import os
import tempfile

import numpy as np

from repro.core import philly_cluster, philly_workload
from repro.service import SchedulerService, SubmitRequest, TenantConfig

cluster = philly_cluster(6, seed=2)
jobs = philly_workload(seed=2)[:12]
rng = np.random.default_rng(0)
arrivals = np.sort(rng.integers(0, 60, size=len(jobs)))
tenants = ["prod" if i % 2 else "batch" for i in range(len(jobs))]

journal = os.path.join(tempfile.mkdtemp(), "scheduler.db")

# -- 1. daemon with a durable journal, two tenants -------------------------
svc = SchedulerService(cluster, policy="sjf-bco", store_path=journal,
                       tenants={"batch": TenantConfig(policy="ff")})
handles = []
for job, arrival, tenant in list(zip(jobs, arrivals, tenants))[:8]:
    handles.append(svc.submit(SubmitRequest(job, int(arrival), tenant)))
print(f"submitted 8 jobs to {journal}")

# -- 2. cancel one while it is still queued --------------------------------
victim = handles[6]
print(f"cancel jid={victim.jid} while queued:", svc.cancel(victim))

# -- 3. crash: run a few rounds, then drop the daemon on the floor ---------
for _ in range(3):
    svc.step()
svc.close()
del svc
print("daemon killed after 3 scheduling rounds; recovering from journal...")

svc = SchedulerService.recover(cluster, journal, policy="sjf-bco",
                               tenants={"batch": TenantConfig(policy="ff")})
for job, arrival, tenant in list(zip(jobs, arrivals, tenants))[8:]:
    svc.submit(SubmitRequest(job, int(arrival), tenant))

# -- 4. drain and show the recovered world ---------------------------------
schedule, sim = svc.drain()
print(f"\nrecovered + drained: {sim.completed} completed, "
      f"avg JCT {sim.avg_jct:.1f} slots "
      f"(queueing {sim.avg_queueing_delay:.1f} of it)\n")
print(svc.table())
svc.close()
