"""Quickstart: the paper's pipeline end-to-end in one minute.

1. Build a multi-tenant GPU cluster and a Philly-style job mix (§7).
2. Schedule it with SJF-BCO and every baseline; simulate actual execution
   under the Eq. (6)-(8) contention model; compare makespans (Fig. 4).
3. Certify the Theorem-5 approximation bound on this instance.
4. Train a reduced llama3.2-1b for a few real steps (the kind of RAR job
   the scheduler places) to show the training substrate is real.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import (ClusterSpec, Scenario, WorkloadSpec, philly_cluster,
                        philly_workload, report, run_scenario)

print("=" * 64)
print("1-2) schedule 160 RAR jobs on 20 servers (paper §7 setting)")
cluster = philly_cluster(20, seed=1)
jobs = philly_workload(seed=1)
results = {}
for name, policy in [("SJF-BCO", "sjf-bco"), ("FF", "ff"),
                     ("LS", "ls"), ("RAND", "rand")]:
    rep = run_scenario(Scenario(cluster=ClusterSpec(num_servers=20, seed=1),
                                workload=WorkloadSpec(seed=1),
                                policy=policy, horizon=1200))
    results[name] = (rep.schedule, rep.sim)
    print(f"   {name:8s} makespan {rep.sim.makespan:6.0f} slots | "
          f"avg JCT {rep.sim.avg_jct:6.1f} | peak contention "
          f"{rep.contention.peak:2d} | util {rep.sim.utilization:.2f}")

print("\n3) Theorem 5 certificate for the SJF-BCO schedule")
sched, sim = results["SJF-BCO"]
rep = report(cluster, jobs, sched, sim)
print(f"   n_g={rep.n_g}  varphi={rep.varphi:.1f}  u/l={rep.u/rep.l:.2f}")
print(f"   makespan {rep.makespan:.0f} <= bound "
      f"{rep.approx_ratio_bound * rep.lower_bound_makespan:.0f} "
      f"(certified={rep.certified})")

print("\n4) train a reduced llama3.2-1b (a real RAR-schedulable job)")
try:
    from repro.dist.steps import make_train_step
except ImportError:
    print("   (skipped: repro.dist unavailable in this environment — see "
          "docs/ARCHITECTURE.md §repro.dist for the substrate layout)")
    print("\nquickstart OK (scheduling)")
    raise SystemExit(0)
from repro.configs import get_config
from repro.data import DataConfig, make_batch
from repro.models import build_model
from repro.models.config import InputShape
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig

cfg = get_config("llama3.2-1b").reduced()
model = build_model(cfg, max_seq=128)
params = model.init(jax.random.PRNGKey(0))
ocfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=20)
opt = adamw.init(ocfg, params)
step = jax.jit(make_train_step(model, ocfg))
shape = InputShape("quick", 128, 8, "train")
losses = []
for i in range(20):
    batch = jax.tree.map(jax.numpy.asarray, make_batch(cfg, shape, i))
    params, opt, m = step(params, opt, batch)
    losses.append(float(m["loss"]))
print(f"   loss: {losses[0]:.3f} -> {losses[-1]:.3f} over 20 steps "
      f"({'improved' if losses[-1] < losses[0] else 'no improvement'})")
assert losses[-1] < losses[0]
print("\nquickstart OK")
