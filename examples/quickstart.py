"""Quickstart: the paper's pipeline end-to-end in one minute.

1. Build a multi-tenant GPU cluster and a Philly-style job mix (§7).
2. Schedule it with SJF-BCO and every baseline; simulate actual execution
   under the Eq. (6)-(8) contention model; compare makespans (Fig. 4).
3. Certify the Theorem-5 approximation bound on this instance.
4. Train a reduced llama3.2-1b for a few real steps (the kind of RAR job
   the scheduler places) to show the training substrate is real.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import (first_fit, list_scheduling, philly_cluster,
                        philly_workload, random_policy, report, simulate,
                        sjf_bco)

print("=" * 64)
print("1-2) schedule 160 RAR jobs on 20 servers (paper §7 setting)")
cluster = philly_cluster(20, seed=1)
jobs = philly_workload(seed=1)
results = {}
for name, policy in [("SJF-BCO", sjf_bco), ("FF", first_fit),
                     ("LS", list_scheduling), ("RAND", random_policy)]:
    sched = policy(cluster, jobs, horizon=1200)
    sim = simulate(cluster, jobs, sched.assignment)
    results[name] = (sched, sim)
    print(f"   {name:8s} makespan {sim.makespan:6.0f} slots | "
          f"avg JCT {sim.avg_jct:6.1f} | peak contention "
          f"{sim.peak_contention:2d} | util {sim.utilization:.2f}")

print("\n3) Theorem 5 certificate for the SJF-BCO schedule")
sched, sim = results["SJF-BCO"]
rep = report(cluster, jobs, sched, sim)
print(f"   n_g={rep.n_g}  varphi={rep.varphi:.1f}  u/l={rep.u/rep.l:.2f}")
print(f"   makespan {rep.makespan:.0f} <= bound "
      f"{rep.approx_ratio_bound * rep.lower_bound_makespan:.0f} "
      f"(certified={rep.certified})")

print("\n4) train a reduced llama3.2-1b (a real RAR-schedulable job)")
from repro.configs import get_config
from repro.data import DataConfig, make_batch
from repro.dist.steps import make_train_step
from repro.models import build_model
from repro.models.config import InputShape
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig

cfg = get_config("llama3.2-1b").reduced()
model = build_model(cfg, max_seq=128)
params = model.init(jax.random.PRNGKey(0))
ocfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=20)
opt = adamw.init(ocfg, params)
step = jax.jit(make_train_step(model, ocfg))
shape = InputShape("quick", 128, 8, "train")
losses = []
for i in range(20):
    batch = jax.tree.map(jax.numpy.asarray, make_batch(cfg, shape, i))
    params, opt, m = step(params, opt, batch)
    losses.append(float(m["loss"]))
print(f"   loss: {losses[0]:.3f} -> {losses[-1]:.3f} over 20 steps "
      f"({'improved' if losses[-1] < losses[0] else 'no improvement'})")
assert losses[-1] < losses[0]
print("\nquickstart OK")
