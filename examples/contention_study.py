"""Contention study: reproduce the paper's §1 motivating observation.

"On a cluster of four-GPU servers ... one RAR job alone finishes in 295 s;
four identical jobs scheduled ACROSS servers take 675 s each (2.3x)."

We recreate the shape of that experiment in the Eq. (6)-(8) model: four
identical 4-GPU RAR jobs on four 4-GPU servers, placed either packed
(one job per server — SJF-BCO's choice) or deliberately straddled
(each ring spanning all four servers — the contention-pathological
placement), and report the slowdown.

Run:  PYTHONPATH=src python examples/contention_study.py
"""
import numpy as np

from repro.core import Cluster, Job, evaluate, simulate

cluster = Cluster(capacities=(4, 4, 4, 4))
jobs = [Job(jid=i, num_gpus=4, iters=3000, grad_size=1.5e-3, batch=32,
            dt_fwd=3e-4, dt_bwd=8e-3) for i in range(4)]

# packed: job i owns server i entirely
packed = [(i, np.arange(4 * i, 4 * i + 4)) for i in range(4)]
# straddled: job i takes GPU i of every server (all rings cross all links)
straddled = [(i, np.array([i, 4 + i, 8 + i, 12 + i])) for i in range(4)]

sim_p = simulate(cluster, jobs, packed)
sim_s = simulate(cluster, jobs, straddled)

print("four identical 4-GPU RAR jobs, four 4-GPU servers")
print(f"  packed   (1 job/server) : makespan {sim_p.makespan:5.0f} slots, "
      f"peak contention {sim_p.peak_contention}")
print(f"  straddled (rings cross) : makespan {sim_s.makespan:5.0f} slots, "
      f"peak contention {sim_s.peak_contention}")
slow = sim_s.makespan / sim_p.makespan
print(f"  slowdown {slow:.2f}x  (paper's motivating example: 675/295 = 2.29x)")

# per-iteration decomposition for one straddled job
Y = cluster.placement_matrix([g for _, g in straddled])
m = evaluate(cluster, jobs, Y)
print("\nper-iteration decomposition (straddled job 0):")
print(f"  exchange {m.exchange[0]*1e3:6.2f} ms | reduce {m.reduce[0]*1e3:5.2f} ms"
      f" | overhead {m.gamma[0]*1e3:5.2f} ms | fp/bp {m.compute[0]*1e3:5.2f} ms")
print(f"  bottleneck bandwidth {m.bandwidth[0]:.3f} GB/slot "
      f"(vs intra-server {cluster.b_intra:.0f})")
assert sim_s.makespan > 1.5 * sim_p.makespan, "contention should bite"
print("\ncontention study OK")
