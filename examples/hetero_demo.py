"""Heterogeneity demo: per-GPU speed tiers and per-link bandwidth classes.

1. Replay the bundled Alibaba-style trace (``examples/sample_trace.csv``)
   through the service daemon twice: once on a homogeneous cluster, once
   on a two-tier cluster where half the servers run at a fraction of the
   nominal GPU speed.  SJF-BCO's placement **visibly flips**: the
   speed-aware schedule shifts GPU-slots off the slow servers (Eq. (1)
   prices a ring at its slowest occupied server's floor).
2. Cross-simulate: run the speed-blind schedule on the two-tier cluster
   next to the speed-aware one.  With MB-scale gradients the reduce term
   ``share / C`` is a small slice of tau, so the model trades queueing
   on the fast servers against slow-server iterations -- the printout
   shows both sides of that trade honestly.
3. A directed straddle vignette: two jobs sharing two servers' uplinks,
   priced under ``"shared"`` links (the paper's Eq. (8) divisor
   ``f(alpha, k)``) vs ``"isolated"`` links (a dedicated fabric, divisor
   exempt) -- the per-iteration time drops accordingly.

Run:  PYTHONPATH=src python examples/hetero_demo.py [--slow-factor 0.05]
"""
import argparse
import dataclasses
import os

import numpy as np

from repro.core import (Cluster, Job, ScheduleRequest, evaluate, get_policy,
                        load_trace, replay_trace, simulate)
from repro.service import SchedulerService

TRACE = os.path.join(os.path.dirname(__file__), "sample_trace.csv")

parser = argparse.ArgumentParser(description=__doc__)
parser.add_argument("--slow-factor", type=float, default=0.05,
                    help="speed of the slow tier relative to the fast one")
args = parser.parse_args()

# -- 1. trace replay on homogeneous vs two-tier clusters -------------------
homog = Cluster((8, 8, 8, 8))
two_tier = dataclasses.replace(
    homog,
    gpu_speeds=(homog.gpu_speed,) * 16
    + (homog.gpu_speed * args.slow_factor,) * 16)
print(f"cluster: 4 servers x 8 GPUs; servers 2-3 at "
      f"{args.slow_factor:.0%} speed in the two-tier variant\n")


def server_loads(cluster, sched):
    """GPU-slots assigned per server over the whole schedule."""
    counts = np.zeros(cluster.num_servers, dtype=int)
    edges = np.concatenate([[0], np.cumsum(cluster.capacities_array)])
    for _, gpus in sched.assignment:
        for g in gpus:
            counts[np.searchsorted(edges, g, side="right") - 1] += 1
    return counts


schedules = {}
for name, cl in (("homogeneous", homog), ("two-tier", two_tier)):
    svc = SchedulerService(cl, policy="sjf-bco")
    replay_trace(svc.daemon, TRACE)
    sched, sim = svc.drain()
    schedules[name] = sched
    print(f"{name:12s}  per-server GPU-slots {server_loads(cl, sched)}"
          f"  makespan {sim.makespan:.0f}  avg JCT {sim.avg_jct:.1f}")

flipped = not np.array_equal(server_loads(homog, schedules["homogeneous"]),
                             server_loads(two_tier, schedules["two-tier"]))
print(f"\nplacement flipped vs homogeneous: {flipped}"
      " (slow servers offloaded)\n")

# -- 2. cross-simulate both schedules on the two-tier cluster --------------
jobs, arrivals = load_trace(TRACE)
for name in ("homogeneous", "two-tier"):
    sim = simulate(two_tier, jobs, schedules[name].assignment,
                   arrivals=arrivals)
    label = "speed-blind" if name == "homogeneous" else "speed-aware"
    print(f"{label} schedule executed on the two-tier cluster: "
          f"makespan {sim.makespan:.0f}, avg JCT {sim.avg_jct:.1f}")
print("(with MB-scale gradients the reduce term is a small slice of tau,"
      "\n so slow-server iterations and fast-server queueing trade off)\n")

# -- 3. shared vs isolated uplinks on a directed straddle ------------------
caps = (2, 2)
straddlers = [Job(jid=j, num_gpus=2, iters=3000, grad_size=1.5e-3,
                  batch=32, dt_fwd=3e-4, dt_bwd=8e-3) for j in range(2)]
Y = np.array([[1, 1], [1, 1]], dtype=np.int64)    # both straddle both
for kind in ("shared", "isolated"):
    cl = Cluster(caps)
    cl = dataclasses.replace(cl, links=((cl.b_inter, kind),) * 2)
    m = evaluate(cl, straddlers, Y)
    print(f"{kind:9s} uplinks: p={m.p[0]}  B={m.bandwidth[0]:.3f} GB/slot"
          f"  tau={m.tau[0]:.5f}  phi={m.phi[0]} iters/slot")
print("isolated uplinks skip the Eq. (8) divisor f(alpha, k):"
      " full bandwidth, more iterations per slot")

# The batch path produces the same placements as the daemon replay --
# the identity guarantee extends to trace-driven arrivals.
batch = get_policy("sjf-bco")(ScheduleRequest(
    cluster=two_tier, jobs=jobs, arrivals=arrivals, horizon=1200))
assert all(j1 == j2 and np.array_equal(g1, g2) for (j1, g1), (j2, g2)
           in zip(batch.assignment, schedules["two-tier"].assignment))
print("\nbatch scheduling == daemon trace replay: identical placements")
