"""Batched multi-arch serving example: prefill + greedy decode with KV
caches / recurrent state across three different model families.

Run:  PYTHONPATH=src python examples/serving_batched.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.dist.steps import make_serve_step
from repro.models import build_model

B, PROMPT, GEN = 4, 12, 24
rng = np.random.default_rng(0)

for arch in ("llama3.2-1b", "xlstm-350m", "whisper-tiny"):
    cfg = get_config(arch).reduced()
    max_seq = PROMPT + GEN
    model = build_model(cfg, max_seq=max_seq)
    params = model.init(jax.random.PRNGKey(1))
    serve = jax.jit(make_serve_step(model))
    cache = model.init_cache(B, max_seq)
    if cfg.family == "audio":
        frames = jnp.asarray(
            rng.standard_normal((B, cfg.enc_frames, cfg.d_model)), jnp.float32)
        cache["enc_out"] = jax.jit(model.encode)(params, frames)

    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (B, PROMPT)), jnp.int32)
    for pos in range(PROMPT - 1):                      # prefill via stepping
        _, _, cache = serve(params, cache, prompt[:, pos],
                            jnp.full((B,), pos, jnp.int32))
    tok = prompt[:, -1]
    t0 = time.time()
    toks = []
    for i in range(GEN):
        tok, logits, cache = serve(params, cache, tok,
                                   jnp.full((B,), PROMPT - 1 + i, jnp.int32))
        toks.append(np.asarray(tok))
    dt = time.time() - t0
    gen = np.stack(toks, 1)
    assert gen.shape == (B, GEN) and (gen >= 0).all() and (gen < cfg.vocab).all()
    print(f"{arch:14s} [{cfg.family:6s}]: {B}x{GEN} tokens in {dt:5.2f}s "
          f"({B*GEN/dt:6.1f} tok/s)  sample: {gen[0][:8].tolist()}")

print("serving_batched OK")
